"""Telecom paging (the paper's §I motivating system, ref [1]).

A cellular network is a directed graph: base stations are nodes, user
movement are edges.  When a user's location is unknown, the network pages a
*set* of cells such that P(user found) >= threshold — exactly MCPrioQ's
cumulative-probability query.  This example simulates user mobility, learns
the transition graph online, and measures paging success vs. cells paged.

    PYTHONPATH=src python examples/telecom_paging.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import mcprioq as mc
from repro.data.synthetic import MarkovGraphSampler


def main():
    n_cells = 400
    mobility = MarkovGraphSampler(num_nodes=n_cells, out_degree=12,
                                  zipf_s=1.6, seed=42)
    cfg = mc.MCConfig(num_rows=512, capacity=16, sort_passes=1)
    state = mc.init(cfg)

    # --- phase 1: learn handover transitions online -----------------------
    for _ in range(80):
        src, dst = mobility.sample_transitions(1024)
        state = mc.update_batch(state, jnp.asarray(src), jnp.asarray(dst),
                                cfg=cfg)

    # --- phase 2: page unknown-location users -----------------------------
    rng = np.random.default_rng(7)
    for threshold in (0.5, 0.8, 0.95):
        last_cell, true_next = mobility.sample_transitions(2000)
        dsts, probs, n_needed = mc.query_threshold(
            state, jnp.asarray(last_cell), threshold, cfg=cfg, max_items=16)
        dsts = np.asarray(dsts)
        found = (dsts == true_next[:, None]).any(axis=1)
        print(f"t={threshold:4.2f}: paged {float(np.mean(n_needed)):5.2f} "
              f"cells on average -> user found {found.mean():6.1%} "
              f"(target {threshold:.0%})")

    # --- phase 3: topology change (new cell tower) + decay ----------------
    # decay lets the chain forget the old neighbour distribution (§II.C)
    state = mc.decay(state, cfg=cfg)
    mobility2 = MarkovGraphSampler(num_nodes=n_cells, out_degree=12,
                                   zipf_s=1.6, seed=43)  # re-planned network
    for _ in range(80):
        src, dst = mobility2.sample_transitions(1024)
        state = mc.update_batch(state, jnp.asarray(src), jnp.asarray(dst),
                                cfg=cfg)
    last_cell, true_next = mobility2.sample_transitions(2000)
    dsts, _, n_needed = mc.query_threshold(
        state, jnp.asarray(last_cell), 0.8, cfg=cfg, max_items=16)
    found = (np.asarray(dsts) == true_next[:, None]).any(axis=1)
    print(f"\nafter topology change + decay: paged "
          f"{float(np.mean(n_needed)):.2f} cells, found {found.mean():.1%} "
          f"(graph re-learned online, no retraining)")


if __name__ == "__main__":
    main()
