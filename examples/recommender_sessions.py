"""Recommender system over user sessions (the paper's primary use case).

Item-to-item collaborative filtering: every user session is a walk over the
item graph; MCPrioQ learns item->item transition counts online and serves
"recommend items until P(match) >= t" queries concurrently with learning
(epoch snapshots = the RCU read side).

    PYTHONPATH=src python examples/recommender_sessions.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import mcprioq as mc
from repro.core.epoch import EpochStore
from repro.data.synthetic import MarkovGraphSampler


def main():
    catalogue = MarkovGraphSampler(num_nodes=1000, out_degree=24,
                                   zipf_s=1.5, seed=1)
    cfg = mc.MCConfig(num_rows=1024, capacity=32, sort_passes=1)
    store = EpochStore(mc.init(cfg))

    hit_at_list, items_shown = [], []
    for epoch in range(40):
        # ---- learner thread: ingest a batch of session transitions -------
        sessions = catalogue.sample_walks(batch=64, length=8)
        src = sessions[:, :-1].reshape(-1)
        dst = sessions[:, 1:].reshape(-1)
        snap = store.acquire()
        try:
            new_state = mc.update_batch(
                snap.state, jnp.asarray(src), jnp.asarray(dst), cfg=cfg)
        finally:
            store.release(snap)
        store.publish(new_state)  # RCU publish: readers never see torn state

        # ---- serving threads: recommend against the published snapshot ---
        snap = store.acquire()
        try:
            cur, nxt = catalogue.sample_transitions(256)
            recs, _, n_needed = mc.query_threshold(
                snap.state, jnp.asarray(cur), 0.8, cfg=cfg, max_items=16)
        finally:
            store.release(snap)
        hits = (np.asarray(recs) == nxt[:, None]).any(axis=1)
        hit_at_list.append(hits.mean())
        items_shown.append(float(np.mean(n_needed)))

    print("epoch  hit-rate  items-shown (t=0.8)")
    for e in (0, 4, 9, 19, 39):
        print(f"{e:5d}  {hit_at_list[e]:7.1%}  {items_shown[e]:6.2f}")
    print(f"\npublished versions: {store.version} "
          f"(readers never blocked; retired {len(store.retired_versions)})")
    assert hit_at_list[-1] > hit_at_list[0]


if __name__ == "__main__":
    main()
