"""Quickstart: the paper's data structure in 60 lines.

Build an online sparse Markov chain, stream transitions into it, query
"items until cumulative probability >= t", and decay it — the full MCPrioQ
API surface.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.core import mcprioq as mc
from repro.data.synthetic import MarkovGraphSampler


def main():
    # a ground-truth random graph with Zipf(1.8) edge probabilities
    graph = MarkovGraphSampler(num_nodes=200, out_degree=16, zipf_s=1.8,
                               seed=0)

    cfg = mc.MCConfig(
        num_rows=256,     # max distinct src nodes tracked
        capacity=32,      # max out-edges kept per node (Space-Saving beyond)
        sort_passes=1,    # odd-even passes per update batch ("bubble sort")
    )
    state = mc.init(cfg)

    # ---- online learning: stream transition batches -----------------------
    for step in range(50):
        src, dst = graph.sample_transitions(512)
        state = mc.update_batch(state, jnp.asarray(src), jnp.asarray(dst),
                                cfg=cfg)
    print("invariants:", mc.check_invariants(state))

    # ---- the paper's query: recommend until P(match) >= 0.9 ---------------
    node = jnp.asarray([7], jnp.int32)
    dsts, probs, n_needed = mc.query_threshold(state, node, 0.9, cfg=cfg,
                                               max_items=16)
    true_dsts, true_probs = graph.true_probs(7)
    print(f"\nnode 7 needs {int(n_needed[0])} items to reach t=0.9 "
          f"(CDF^-1 of its Zipf edges)")
    print("learned:", [(int(d), round(float(p), 3))
                       for d, p in zip(dsts[0], probs[0]) if d >= 0][:5])
    print("truth  :", [(int(d), round(float(p), 3))
                       for d, p in zip(true_dsts[:5], true_probs[:5])])

    # ---- model decay (§II.C): halve counts, evict dead edges --------------
    live_before = int(jnp.sum(state.slabs.cnt > 0))
    state = mc.decay(state, cfg=cfg)
    live_after = int(jnp.sum(state.slabs.cnt > 0))
    print(f"\ndecay: {live_before} -> {live_after} live edges "
          f"(distribution preserved, cold edges evicted)")


if __name__ == "__main__":
    main()
