"""Quickstart: the paper's data structure, then durable elastic serving.

Part 1 builds an online sparse Markov chain, streams transitions into it,
queries "items until cumulative probability >= t", and decays it — the full
MCPrioQ API surface.

Part 2 is the production story (DESIGN.md §10): the same chain behind the
sharded serving engine with snapshots and a write-ahead log — save, kill
the "process", and restore **at a different shard count**, getting the
same answers back.

Part 3 is kill-under-load (DESIGN.md §12): arm fault-injection failpoints
on the live write path and watch the retry ladder absorb a transient disk
flake, a persistent ENOSPC poison writes while reads keep serving, and
``restore()`` heal the poisoned engine bit-exactly.

Part 4 is telemetry (DESIGN.md §13): arm the lock-free metrics registry,
scrape the Prometheus surface ``launch/serve.py --metrics-port`` serves
(latency quantiles, per-bucket traffic), provoke a write-path poison, and
read the flight recorder's incident dump.

    PYTHONPATH=src python examples/quickstart.py

``--chaos`` additionally runs the real crash soak: a serving subprocess
SIGKILLed mid-append/mid-snapshot a few times, each death verified
bit-exact against a deterministic replay oracle (``tools/chaos/soak.py``).
"""

import os
import shutil
import tempfile

# part 2 reshards a 4-shard chain onto 2 shards; fake the devices before
# jax initialises (harmless on a real multi-device host)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np
import jax.numpy as jnp

from repro.core import mcprioq as mc
from repro.core import sharded as sh
from repro.data.synthetic import MarkovGraphSampler
from repro.serve.engine import ShardedEngine, ShardedServeConfig


def part1_the_data_structure():
    # a ground-truth random graph with Zipf(1.8) edge probabilities
    graph = MarkovGraphSampler(num_nodes=200, out_degree=16, zipf_s=1.8,
                               seed=0)

    cfg = mc.MCConfig(
        num_rows=256,     # max distinct src nodes tracked
        capacity=32,      # max out-edges kept per node (Space-Saving beyond)
        sort_passes=1,    # odd-even passes per update batch ("bubble sort")
    )
    state = mc.init(cfg)

    # ---- online learning: stream transition batches -----------------------
    for step in range(50):
        src, dst = graph.sample_transitions(512)
        state = mc.update_batch(state, jnp.asarray(src), jnp.asarray(dst),
                                cfg=cfg)
    print("invariants:", mc.check_invariants(state))

    # ---- the paper's query: recommend until P(match) >= 0.9 ---------------
    node = jnp.asarray([7], jnp.int32)
    dsts, probs, n_needed = mc.query_threshold(state, node, 0.9, cfg=cfg,
                                               max_items=16)
    true_dsts, true_probs = graph.true_probs(7)
    print(f"\nnode 7 needs {int(n_needed[0])} items to reach t=0.9 "
          f"(CDF^-1 of its Zipf edges)")
    print("learned:", [(int(d), round(float(p), 3))
                       for d, p in zip(dsts[0], probs[0]) if d >= 0][:5])
    print("truth  :", [(int(d), round(float(p), 3))
                       for d, p in zip(true_dsts[:5], true_probs[:5])])

    # ---- model decay (§II.C): halve counts, evict dead edges --------------
    live_before = int(jnp.sum(state.slabs.cnt > 0))
    state = mc.decay(state, cfg=cfg)
    live_after = int(jnp.sum(state.slabs.cnt > 0))
    print(f"\ndecay: {live_before} -> {live_after} live edges "
          f"(distribution preserved, cold edges evicted)")


def part2_durable_elastic_serving():
    """save -> kill -> restore at a different shard count (DESIGN.md §10)."""
    snap_dir = tempfile.mkdtemp(prefix="mcprioq-snap-")
    wal_dir = tempfile.mkdtemp(prefix="mcprioq-wal-")
    base = mc.MCConfig(num_rows=512, capacity=32, sort_passes=4)
    graph = MarkovGraphSampler(num_nodes=300, out_degree=12, zipf_s=1.5,
                               seed=3)

    def engine_at(num_shards):
        return ShardedEngine(ShardedServeConfig(
            sharded=sh.ShardedConfig(base=base, num_shards=num_shards,
                                     bucket_factor=4.0),
            decay_threshold=1 << 30,
            snapshot_dir=snap_dir,   # arms checkpoint()/restore()
            wal_dir=wal_dir,         # every batch durably logged pre-apply
            snapshot_every=4))       # background snapshot every 4 observes

    # every learned edge of a row, order-canonicalised: elastic restore
    # conserves counts exactly but *settles* the order permutation, while a
    # live chain's order is only approximately sorted (A2) — so the
    # order-independent view is what must match across the kill
    def canonical_edges(engine, queries):
        d, p, n = engine.query(queries, threshold=0.999999, max_items=32)
        d, p = np.asarray(d), np.asarray(p)
        key = np.lexsort((d, -p), axis=-1)
        return (np.take_along_axis(d, key, 1),
                np.take_along_axis(p, key, 1), np.asarray(n))

    # ---- serve at N=4 shards: observe, snapshot on cadence ----------------
    engine = engine_at(4)
    for _ in range(6):
        src, dst = graph.sample_transitions(1024)
        engine.observe(src, dst)
    engine.checkpoint()              # explicit snapshot (cadence also ran)
    src, dst = graph.sample_transitions(1024)
    engine.observe(src, dst)         # after the snapshot: WAL-only
    queries = np.arange(32, dtype=np.int32)
    before = canonical_edges(engine, queries)
    print(f"\nserved {engine.stats['updates']} batches at 4 shards, "
          f"{engine.stats['snapshots']} snapshots, "
          f"WAL through seq {engine._seq}")

    # ---- kill: drop every in-memory reference -----------------------------
    del engine                       # all device + host state is gone

    # ---- restore at M=2 shards: elastic reshard + WAL replay --------------
    revived = engine_at(2)
    info = revived.restore()
    print(f"restored snapshot step {info['step']} at 2 shards "
          f"(mode={info['mode']}, replayed {info['replayed']} WAL batches)")
    after = canonical_edges(revived, queries)
    same = all(np.array_equal(a, b) for a, b in zip(before, after))
    print(f"learned edges after elastic restore match pre-kill chain: {same}")
    assert same

    shutil.rmtree(snap_dir)
    shutil.rmtree(wal_dir)


def part3_kill_under_load():
    """faults on the live write path: retry -> poison -> restore-heal
    (DESIGN.md §12)."""
    import errno

    from repro import faults
    from repro.runtime.fault_tolerance import (EngineWriteUnavailable,
                                               RetryPolicy)

    snap_dir = tempfile.mkdtemp(prefix="mcprioq-chaos-snap-")
    wal_dir = tempfile.mkdtemp(prefix="mcprioq-chaos-wal-")
    base = mc.MCConfig(num_rows=256, capacity=16, sort_passes=2)
    graph = MarkovGraphSampler(num_nodes=200, out_degree=12, zipf_s=1.5,
                               seed=7)

    def engine():
        return ShardedEngine(ShardedServeConfig(
            sharded=sh.ShardedConfig(base=base, num_shards=1,
                                     bucket_factor=4.0),
            decay_threshold=1 << 30, snapshot_dir=snap_dir, wal_dir=wal_dir,
            wal_fsync="always",
            retry=RetryPolicy(max_attempts=3, base_delay_s=1e-3)))

    eng = engine()
    batches = [graph.sample_transitions(512) for _ in range(4)]
    eng.observe(*batches[0])
    eng.checkpoint()

    # ---- a transient disk flake: the retry ladder absorbs it --------------
    faults.arm("wal.append.write", faults.FaultInjected("wal.append.write"),
               count=1)
    eng.observe(*batches[1])
    print(f"\ntransient WAL fault: retried {eng.stats['wal_retries']}x, "
          f"batch applied (updates={eng.stats['updates']}), "
          f"write_available={eng.write_available}")

    # ---- persistent ENOSPC: writes poison, reads keep serving -------------
    faults.arm("wal.append.write",
               faults.FaultInjected("wal.append.write", errno.ENOSPC))
    try:
        eng.observe(*batches[2])
    except EngineWriteUnavailable as e:
        print(f"persistent fault escalated: {e}")
    faults.reset()
    queries = np.arange(32, dtype=np.int32)
    before = eng.query(queries, threshold=0.9, max_items=16)
    print(f"poisoned engine still answers reads "
          f"(write_available={eng.write_available}, "
          f"write_errors={eng.stats['write_errors']})")

    # ---- kill + restore: replay heals the poison --------------------------
    del eng
    revived = engine()
    info = revived.restore()
    after = revived.query(queries, threshold=0.9, max_items=16)
    same = all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(before, after))
    print(f"restored step {info['step']}, replayed {info['replayed']} WAL "
          f"batches: write_available={revived.write_available}, "
          f"pre-kill answers match: {same}")
    assert same and revived.write_available

    shutil.rmtree(snap_dir)
    shutil.rmtree(wal_dir)


def part4_telemetry_and_flight_recorder():
    """armed telemetry: scrape the /metrics surface, provoke a poison,
    read the flight-recorder incident dump (DESIGN.md §13)."""
    import errno
    import json
    import urllib.request

    from repro import faults
    from repro.obs import metrics as obs
    from repro.obs.export import MetricsServer
    from repro.runtime.fault_tolerance import (EngineWriteUnavailable,
                                               RetryPolicy)

    wal_dir = tempfile.mkdtemp(prefix="mcprioq-obs-wal-")
    incident_dir = tempfile.mkdtemp(prefix="mcprioq-obs-inc-")
    base = mc.MCConfig(num_rows=256, capacity=16, sort_passes=2)
    graph = MarkovGraphSampler(num_nodes=200, out_degree=12, zipf_s=1.5,
                               seed=11)

    obs.arm()               # histograms/spans/vectors/incidents on
    try:
        eng = ShardedEngine(ShardedServeConfig(
            sharded=sh.ShardedConfig(base=base, num_shards=1,
                                     bucket_factor=4.0),
            decay_threshold=1 << 30, wal_dir=wal_dir, wal_fsync="always",
            incident_dir=incident_dir,
            retry=RetryPolicy(max_attempts=3, base_delay_s=1e-3)))
        for _ in range(4):
            eng.observe(*graph.sample_transitions(512))
        eng.query(np.arange(32, dtype=np.int32), threshold=0.9,
                  max_items=16)

        # ---- scrape the same surface `launch/serve.py --metrics-port`
        # serves: latency quantiles + per-virtual-bucket traffic ----------
        server = MetricsServer(eng.metrics, port=0).start()
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{server.port}/metrics") as resp:
                text = resp.read().decode()
        finally:
            server.close()
        shown = [ln for ln in text.splitlines()
                 if ln.startswith(("mcq_engine_observe_seconds{",
                                   "mcq_engine_query_seconds{",
                                   "mcq_bucket_traffic{"))]
        print("\nscraped /metrics (observe/query quantiles, bucket "
              "traffic):")
        for ln in shown[:8]:
            print("  " + ln)

        # ---- provoke a fault: persistent ENOSPC poisons the write path --
        faults.arm("wal.append.write",
                   faults.FaultInjected("wal.append.write", errno.ENOSPC))
        try:
            eng.observe(*graph.sample_transitions(512))
        except EngineWriteUnavailable:
            pass
        faults.reset()

        # ---- the flight recorder dumped the incident --------------------
        dumps = sorted(os.listdir(incident_dir))
        with open(os.path.join(incident_dir, dumps[0])) as fh:
            incident = json.load(fh)
        print(f"incident dump {dumps[0]}: reason={incident['reason']!r}, "
              f"{len(incident['spans'])} flight-recorder spans, "
              f"{len(incident['deltas'])} scalar deltas since baseline")
        assert incident["schema"] == "mcq-incident-v1" and incident["spans"]
    finally:
        obs.disarm()
        faults.reset()
    shutil.rmtree(wal_dir)
    shutil.rmtree(incident_dir)


def chaos_soak_demo(kills=3):
    """the real thing: SIGKILL a serving subprocess, verify bit-exact
    recovery against the deterministic replay oracle (tools/chaos/soak.py)."""
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if repo not in sys.path:
        sys.path.insert(0, repo)
    from tools.chaos.soak import run_soak
    result = run_soak(kills, rows=128, batch=64, snapshot_every=3)
    assert result["ok"], "crash soak diverged"
    print(f"\nchaos soak: {kills} kills, all recoveries bit-exact")


if __name__ == "__main__":
    import sys
    part1_the_data_structure()
    part2_durable_elastic_serving()
    part3_kill_under_load()
    part4_telemetry_and_flight_recorder()
    if "--chaos" in sys.argv[1:]:
        chaos_soak_demo()
