"""End-to-end driver: train a small LM for a few hundred steps, then serve it
with the MCPrioQ speculative drafter (deliverable b).

Training uses the full production stack (sharded data pipeline, pjit train
step, AdamW, checkpointing); serving uses the engine with online n-gram
drafting — the paper's structure learning from the model's own output stream.

    PYTHONPATH=src python examples/lm_speculative_serve.py \
        --steps 300 --arch starcoder2-3b
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core import mcprioq as mcq
from repro.core import speculative as spec
from repro.launch.train import run as train_run
from repro.models import Model
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/mcprioq_quickstart_ckpt")
    args = ap.parse_args()

    # ---- train (~100M-class reduced config, few hundred steps) ------------
    print(f"== training {args.arch} (reduced config) for {args.steps} steps")
    losses = train_run(arch=args.arch, smoke=True, steps=args.steps,
                       batch=8, seq=128, ckpt_dir=args.ckpt_dir,
                       ckpt_every=100)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training must reduce loss"

    # ---- serve with the MCPrioQ drafter ------------------------------------
    print("\n== serving with online n-gram speculative drafting")
    cfg = smoke_config(args.arch)
    model = Model(cfg)
    # reuse trained params from the checkpoint
    from repro.checkpoint import ckpt as ckpt_mod
    from repro.train.train_step import TrainConfig, abstract_state
    shapes = abstract_state(model, TrainConfig())
    state, _ = ckpt_mod.restore(shapes, args.ckpt_dir)
    params = state.params

    engine = Engine(model, params, ServeConfig(
        max_new_tokens=48, max_cache_len=256, draft_len=4,
        ngram=spec.NGramConfig(order=2, mc=mcq.MCConfig(
            num_rows=8192, capacity=32, sort_passes=1))))

    rng = np.random.default_rng(0)
    t0 = time.time()
    total = 0
    for req in range(6):
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                             jnp.int32)
        out = engine.generate({"tokens": prompt}, jax.random.key(req))
        total += out.size
    dt = time.time() - t0
    plain_calls = 6 * (48 - 1)  # model calls plain greedy would need
    print(f"{total} tokens in {dt:.1f}s ({total/dt:.1f} tok/s), "
          f"model calls {engine.stats['model_calls']} vs {plain_calls} plain "
          f"({plain_calls / max(engine.stats['model_calls'], 1):.2f}x), "
          f"draft acceptance {engine.acceptance_rate:.1%} "
          f"(drafter version {engine.drafter_store.version})")


if __name__ == "__main__":
    main()
